"""Repo-specific concurrency & JIT discipline analyzer.

Every layer of the LLMS stack shipped with a latent concurrency bug
that only end-to-end load surfaced (the PR 3 AsyncSwapper
self-deadlock, the PR 6 restore-vs-AoT ``os.replace`` race, the PR 7
stats snapshot race, the PR 8 hung-IO requeues).  This package encodes
those bug classes as STATIC rules over the repo's own idioms (AST
only, stdlib only — run ``python -m repro.analysis``) plus a runtime
complement (``analysis.runtime``: a lock-order witness, zero-cost
unless ``LLMS_LOCK_WITNESS=1``).

Checkers (DESIGN.md "Concurrency invariants"):

``lock``    lock-discipline: ``*_locked`` / ``@requires_lock`` methods
            must be called with the owning lock held; blocking
            operations (Future.result/wait, AsyncSwapper.wait/flush,
            DiskStore IO, jitted-entry execution, time.sleep) must not
            run under a narrow lock; worker-pool job bodies must never
            synchronize on pool futures (the PR 3 deadlock class);
            chunk-file reads must be ordered behind in-flight same-key
            AoT writes (the PR 6 race class).
``jit``     functions passed to ``jax.jit`` must not close over
            mutable ``self`` state or call host-side-effect functions;
            jit-cache keys must be hashable content fingerprints —
            never ``id(...)`` (the PR 3 cache-keying bug, as a rule).
``shared``  attributes written by worker-thread-reachable code and
            touched from router/dispatcher code must be written under
            a lock or appear in the audited allowlist
            (``analysis.config.SHARED_STATE_ALLOWLIST``).

Findings diff against the committed ``analysis_baseline.json`` —
grandfathered fingerprints don't block, new ones do (CI ``analysis``
job).
"""
from repro.analysis.findings import Finding
from repro.analysis.markers import requires_lock, requires_serialized

__all__ = ["Finding", "requires_lock", "requires_serialized"]
