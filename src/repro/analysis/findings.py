"""Finding record + stable fingerprints for baseline diffing."""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict


@dataclass(frozen=True)
class Finding:
    """One analyzer finding.

    ``fingerprint`` deliberately excludes the line number so pure code
    motion doesn't churn ``analysis_baseline.json``: identity is
    (checker, rule, file, scope, message).
    """
    checker: str          # "lock" | "jit" | "shared"
    rule: str             # e.g. "blocking-under-lock"
    file: str             # repo-relative posix path
    line: int
    scope: str            # enclosing qualname, e.g. "AsyncSwapper.wait"
    message: str

    @property
    def fingerprint(self) -> str:
        ident = "|".join((self.checker, self.rule, self.file,
                          self.scope, self.message))
        return hashlib.sha1(ident.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict[str, Any]:
        return {"checker": self.checker, "rule": self.rule,
                "file": self.file, "line": self.line,
                "scope": self.scope, "message": self.message,
                "fingerprint": self.fingerprint}

    def render(self) -> str:
        return (f"{self.file}:{self.line}: [{self.checker}/{self.rule}] "
                f"{self.scope}: {self.message}")

    def sort_key(self):
        return (self.file, self.line, self.checker, self.rule,
                self.message)
