"""Runtime lock-order witness (the analyzer's dynamic complement).

The static lock checker (``repro.analysis.lockcheck``) reasons about
what the source *says*; this module watches what a run actually
*does*: every lock the core tier creates goes through
``witness_lock`` / ``witness_rlock`` / ``witness_condition``, which
return plain ``threading`` primitives unless ``LLMS_LOCK_WITNESS=1``
is set — zero overhead in production.

With the witness on, each named lock is wrapped in ``OrderedLock``:
acquiring lock B while holding lock A records the edge ``A -> B`` in a
process-global order graph, and an acquisition that would close a
cycle raises ``LockOrderError`` *before blocking* — an
about-to-deadlock interleaving fails the test run with the offending
chain in the message instead of hanging until the CI timeout.  Edges
are recorded by lock NAME (one node per lock role, not per instance),
matching the lock hierarchy DESIGN.md documents:

    scheduler.svc  >  scheduler.cv / requests.stream  >
    residency.flags  >  swap.pending  >  store.bytes  >
    faults.registry / restore.io

Re-entrant acquisition (RLock) and same-name sibling instances never
add self-edges.  CI runs the tier-1 shards and the ``smoke_ci``
scenario leg with the witness enabled.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Set, Tuple


class LockOrderError(RuntimeError):
    """An acquisition would close a cycle in the lock-order graph."""


def witness_active() -> bool:
    return os.environ.get("LLMS_LOCK_WITNESS", "") not in ("", "0")


# process-global order graph: name -> names acquired while it was held.
# _EDGE_SITES keeps one example (thread name) per edge for diagnostics.
_REG_LOCK = threading.Lock()
_EDGES: Dict[str, Set[str]] = {}
_EDGE_SITES: Dict[Tuple[str, str], str] = {}
_TLS = threading.local()


def _held_stack() -> List[str]:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def _find_path(src: str, dst: str) -> Optional[List[str]]:
    """DFS path src -> dst in _EDGES (caller holds _REG_LOCK)."""
    seen = {src}
    stack = [(src, [src])]
    while stack:
        node, path = stack.pop()
        for nxt in sorted(_EDGES.get(node, ())):
            if nxt == dst:
                return path + [nxt]
            if nxt not in seen:
                seen.add(nxt)
                stack.append((nxt, path + [nxt]))
    return None


def _note_attempt(name: str):
    """Record edges held-name -> name; raise on a would-be cycle.

    Called BEFORE the underlying acquire so a true inversion surfaces
    as an exception, not a hang."""
    held = _held_stack()
    if not held or name in held:        # re-entry / sibling: no self-edge
        return
    for prev in dict.fromkeys(held):    # distinct, order-preserving
        if prev == name:
            continue
        with _REG_LOCK:
            if name in _EDGES.get(prev, ()):
                continue
            back = _find_path(name, prev)
            if back is not None:
                chain = " -> ".join(back)
                raise LockOrderError(
                    f"lock-order inversion: acquiring '{name}' while "
                    f"holding '{prev}' (thread "
                    f"{threading.current_thread().name}), but the "
                    f"recorded order already has {chain}")
            _EDGES.setdefault(prev, set()).add(name)
            _EDGE_SITES[(prev, name)] = threading.current_thread().name


class OrderedLock:
    """Lock wrapper that feeds the order graph.  Wraps a Lock or RLock;
    also usable as the inner lock of a ``threading.Condition`` (only
    exposes acquire/release/context-manager, so Condition falls back to
    its generic ``_is_owned`` probe, which these semantics support for
    non-reentrant inner locks)."""

    def __init__(self, name: str, inner=None):
        self.name = name
        self._inner = inner if inner is not None else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1):
        if blocking:
            _note_attempt(self.name)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            if not blocking:
                # try-acquire: only a SUCCESSFUL probe is an acquisition
                _note_attempt(self.name)
            _held_stack().append(self.name)
        return ok

    def release(self):
        self._inner.release()
        st = _held_stack()
        # remove the most recent entry for this name (balanced with the
        # per-acquisition push; tolerates out-of-order sibling release)
        for i in range(len(st) - 1, -1, -1):
            if st[i] == self.name:
                del st[i]
                break

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self) -> bool:
        return self._inner.locked()


def witness_lock(name: str):
    """-> ``threading.Lock()`` (witness off) or a named OrderedLock."""
    if witness_active():
        return OrderedLock(name, threading.Lock())
    return threading.Lock()


def witness_rlock(name: str):
    if witness_active():
        return OrderedLock(name, threading.RLock())
    return threading.RLock()


def witness_condition(name: str) -> threading.Condition:
    """Condition whose inner lock feeds the order graph (witness on)."""
    if witness_active():
        return threading.Condition(OrderedLock(name, threading.Lock()))
    return threading.Condition()


def order_graph() -> Dict[str, Set[str]]:
    """Snapshot of the recorded acquisition-order edges (tests/debug)."""
    with _REG_LOCK:
        return {k: set(v) for k, v in _EDGES.items()}


def reset_witness():
    """Clear the order graph (test isolation)."""
    with _REG_LOCK:
        _EDGES.clear()
        _EDGE_SITES.clear()
