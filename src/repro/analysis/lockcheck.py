"""Lock-discipline checker + the scan pass shared with the
thread-shared-state audit.

Rules (finding rule ids):

``locked-call``          a ``*_locked`` / ``@requires_lock`` function
                         called without the owning lock held (lexically
                         inside ``with <lock>``, or from a function
                         whose own contract holds the same lock).
``serialized-call``      a ``@requires_serialized`` function called
                         from outside the dispatcher surface (no
                         ``_svc_lock`` held, caller not serialized or
                         allowlisted).
``blocking-under-lock``  a blocking operation (``config.BLOCKING_CALLS``)
                         invoked while a NARROW lock is held.  Coarse
                         locks (``config.COARSE_LOCKS``) are exempt —
                         holding ``_svc_lock`` across service work is
                         the engine's design.
``blocking-in-worker``   a pool job body / done-callback / thread
                         target synchronizing on other pool work
                         (``Future.result``/``wait``/``flush``/``join``)
                         — the PR 3 AsyncSwapper self-deadlock class.
``unordered-store-read`` a chunk-file read of a store path with no
                         preceding same-function ordering point
                         (``swapper.wait``/``swapper.submit``/own
                         ``write_chunk_file``) — the PR 6
                         restore-vs-AoT ``os.replace`` race class.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import config
from repro.analysis.astpass import (FunctionInfo, Program, attr_chain)
from repro.analysis.findings import Finding

_ORDER_ATTRS = {"wait", "submit", "read", "read_async", "flush"}
_READ_FNS = {"read_chunk_file", "verify_chunk_file"}


@dataclass
class WriteSite:
    fn: FunctionInfo
    key: Tuple[str, str]               # (owner class | module, attr)
    line: int
    guarded: bool


@dataclass
class ScanData:
    """Side products of the lock scan, consumed by sharedstate."""
    writes: List[WriteSite] = field(default_factory=list)
    reads: Dict[str, Set[Tuple[str, str]]] = field(default_factory=dict)
    edges: Dict[str, Set[str]] = field(default_factory=dict)
    by_ident: Dict[str, FunctionInfo] = field(default_factory=dict)


def run(program: Program) -> Tuple[List[Finding], ScanData]:
    findings: List[Finding] = []
    data = ScanData()
    for mod in program.modules:
        for fn in mod.all_functions:
            data.by_ident.setdefault(fn.qualname + "@" + mod.modname, fn)
    # pass 1: per-function scan (also discovers worker-marked functions)
    for mod in program.modules:
        for fn in mod.all_functions:
            _FnScanner(program, fn, findings, data).scan()
    # pass 2: worker bodies (marks accumulated program-wide in pass 1)
    for mod in program.modules:
        for fn in mod.all_functions:
            if fn.worker or fn.ident in config.WORKER_ENTRIES:
                _WorkerScanner(program, fn, findings).scan()
    return findings, data


def _match_blocking(program: Program, fn: FunctionInfo, call: ast.Call,
                    registry, held: List[str]) -> Optional[dict]:
    f = call.func
    chain = attr_chain(f)
    for e in registry:
        if "attr" in e:
            if not (isinstance(f, ast.Attribute) and f.attr == e["attr"]):
                continue
            rc = chain[:-1] if chain else ()
            if "recv" in e and not any(r in rc for r in e["recv"]):
                continue
            if "not_recv" in e and any(r in rc for r in e["not_recv"]):
                continue                # e.g. os.path.join is not a join
            if e.get("allow_held"):
                tok = program.lock_token(f.value, fn)
                if tok is not None and tok in held:
                    continue
            return e
        if "attr_suffix" in e:
            if isinstance(f, ast.Attribute) and \
                    f.attr.endswith(e["attr_suffix"]):
                return e
        if "name" in e:
            if isinstance(f, ast.Name) and f.id == e["name"]:
                return e
    return None


class _FnScanner:
    """One function body: lock rules + write/read/edge collection."""

    def __init__(self, program: Program, fn: FunctionInfo,
                 findings: List[Finding], data: ScanData):
        self.p = program
        self.fn = fn
        self.findings = findings
        self.data = data
        self.reads = data.reads.setdefault(self._node_id(fn), set())
        self.edges = data.edges.setdefault(self._node_id(fn), set())
        self.globals_decl: Set[str] = set()
        for n in ast.walk(fn.node):
            if isinstance(n, ast.Global):
                self.globals_decl.update(n.names)
        # contract-held locks on entry (the function's own invariant)
        held: List[str] = []
        tok = program.contract_token(fn)
        if tok:
            held.append(tok)
        if self._serialized_context():
            held.append("ServiceRouter._svc_lock")
        self.entry_held = held
        # ordering events / read sites for the unordered-read rule
        self.order_lines: List[int] = []
        self.read_sites: List[Tuple[int, str]] = []
        self.in_order_call = 0

    @staticmethod
    def _node_id(fn: FunctionInfo) -> str:
        return f"{fn.qualname}@{fn.module.modname}"

    def _serialized_context(self) -> bool:
        cur: Optional[FunctionInfo] = self.fn
        while cur is not None:
            if cur.serialized:
                return True
            cur = cur.parent
        return False

    def _allowlisted_serial_caller(self) -> bool:
        cur: Optional[FunctionInfo] = self.fn
        while cur is not None:
            if cur.name in config.SERIALIZED_CALLER_ALLOWLIST or \
                    cur.ident in config.SERIALIZED_CALLER_ALLOWLIST:
                return True
            cur = cur.parent
        return False

    def _emit(self, rule: str, line: int, message: str):
        self.findings.append(Finding(
            checker="lock", rule=rule, file=self.fn.module.relpath,
            line=line, scope=self.fn.qualname, message=message))

    def scan(self):
        for stmt in self.fn.node.body:
            self._scan(stmt, list(self.entry_held))
        # unordered-read resolution: a read site is ordered when ANY
        # ordering point appears earlier in the same function (or the
        # function's contract is itself a worker job body — ordering
        # then happened at submit time)
        for line, what in self.read_sites:
            if any(ol <= line for ol in self.order_lines):
                continue
            self._emit(
                "unordered-store-read", line,
                f"{what} reads a store path with no preceding "
                f"swapper.wait/submit ordering point: races an "
                f"in-flight same-key AoT write's os.replace "
                f"(PR 6 class)")

    # -- recursion ------------------------------------------------------ #
    def _scan(self, node, held: List[str]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                      # scanned as its own function
        if isinstance(node, ast.Lambda):
            self._scan_ordering_only(node.body)
            return                      # deferred body: lock not held
        if isinstance(node, (ast.With, ast.AsyncWith)):
            new = list(held)
            for item in node.items:
                self._scan(item.context_expr, held)
                tok = self.p.lock_token(item.context_expr, self.fn)
                if tok:
                    new.append(tok)
            for stmt in node.body:
                self._scan(stmt, new)
            return
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                self._record_write_target(tgt, held, node.lineno)
        if isinstance(node, ast.Call):
            self._check_call(node, held)
        if isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, ast.Load):
            self._record_read(node)
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in self.fn.module.mutable_globals:
                self.reads.add((self.fn.module.modname, node.id))
        for child in ast.iter_child_nodes(node):
            self._scan(child, held)

    def _scan_ordering_only(self, node):
        """Lambda bodies still participate in the unordered-read rule
        (``with_retries(lambda: read_chunk_file(...))``)."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._note_ordering(sub)

    # -- calls ----------------------------------------------------------- #
    def _check_call(self, node: ast.Call, held: List[str]):
        chain = attr_chain(node.func)
        name = chain[-1] if chain else None
        self._note_ordering(node)
        # worker discovery: functions handed to pools/threads/callbacks
        if name in ("submit", "add_done_callback"):
            for arg in node.args:
                self._mark_worker_arg(arg)
        elif name == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    self._mark_worker_arg(kw.value)
        target = self.p.resolve_call(node, self.fn)
        if target is not None:
            self.edges.add(self._node_id(target))
        # rule: locked-call
        req = self.p.contract_token(target) if target is not None else (
            "?" if name and name.endswith("_locked") else None)
        if req is not None and not self._lock_satisfied(req, held):
            want = req if req != "?" else "its owning lock"
            self._emit("locked-call", node.lineno,
                       f"call to {name} requires {want} held "
                       f"(held: {sorted(set(held)) or 'none'})")
        # rule: serialized-call
        if target is not None and target.serialized:
            ok = (self._serialized_context()
                  or any(t in config.COARSE_LOCKS for t in held)
                  or self._allowlisted_serial_caller())
            if not ok:
                self._emit(
                    "serialized-call", node.lineno,
                    f"call to {target.qualname} requires the "
                    f"dispatcher (serialized under "
                    f"ServiceRouter._svc_lock)")
        # rule: blocking-under-lock (narrow locks only)
        narrow = [t for t in held if t not in config.COARSE_LOCKS]
        if narrow:
            e = _match_blocking(self.p, self.fn, node,
                                config.BLOCKING_CALLS, held)
            if e is not None:
                what = name or "<call>"
                self._emit("blocking-under-lock", node.lineno,
                           f"{what}(): {e['why']} while holding "
                           f"{sorted(set(narrow))}")

    def _lock_satisfied(self, req: str, held: List[str]) -> bool:
        if req == "?":
            return bool(held)
        if req in held:
            return True
        # unresolved-owner tokens ("?.X") satisfy a same-attr contract
        attr = req.rsplit(".", 1)[-1].rsplit(":", 1)[-1]
        return any(h.startswith("?") and h.endswith(f".{attr}")
                   for h in held)

    def _mark_worker_arg(self, arg):
        if isinstance(arg, ast.Lambda):
            _WorkerScanner(self.p, self.fn, self.findings,
                           override_node=arg.body).scan()
            return
        chain = attr_chain(arg)
        if chain is None:
            return
        if len(chain) == 1:
            cur: Optional[FunctionInfo] = self.fn
            while cur is not None:
                if chain[0] in cur.children:
                    cur.children[chain[0]].worker = True
                    return
                cur = cur.parent
            got = self.fn.module.functions.get(chain[0])
            if got is not None:
                got.worker = True
        elif chain[0] == "self" and len(chain) == 2 and self.fn.cls:
            m = self.fn.cls.methods.get(chain[1])
            if m is not None:
                m.worker = True

    # -- unordered-read bookkeeping -------------------------------------- #
    def _note_ordering(self, node: ast.Call):
        chain = attr_chain(node.func)
        name = chain[-1] if chain else None
        if chain and len(chain) >= 2 and name in _ORDER_ATTRS and \
                "swapper" in chain[:-1]:
            self.order_lines.append(node.lineno)
            return
        if name == "write_chunk_file":
            self.order_lines.append(node.lineno)
            return
        if name in _READ_FNS and self._has_store_path_arg(node):
            self.read_sites.append((node.lineno, name))

    @staticmethod
    def _has_store_path_arg(node: ast.Call) -> bool:
        for arg in node.args:
            for sub in ast.walk(arg):
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr == "_path":
                    return True
        return False

    # -- shared-state collection ----------------------------------------- #
    def _record_write_target(self, tgt, held: List[str], line: int):
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self._record_write_target(e, held, line)
            return
        base = tgt
        while isinstance(base, ast.Subscript):
            base = base.value
        chain = attr_chain(base)
        if chain is None:
            return
        key: Optional[Tuple[str, str]] = None
        if chain[0] == "self" and len(chain) >= 2 and self.fn.cls:
            owner = self.p.resolve_class_chain(chain, self.fn.cls)
            if owner is not None:
                key = (owner.name, chain[-1])
        elif len(chain) == 1:
            nm = chain[0]
            if nm in self.fn.module.mutable_globals or \
                    nm in self.globals_decl:
                key = (self.fn.module.modname, nm)
        if key is None:
            return
        if self.fn.name == "__init__" and self.fn.parent is None:
            return                       # construction precedes sharing
        self.data.writes.append(WriteSite(
            fn=self.fn, key=key, line=line,
            guarded=any(t != "?" for t in held)))

    def _record_read(self, node: ast.Attribute):
        chain = attr_chain(node)
        if chain and chain[0] == "self" and len(chain) >= 2 and \
                self.fn.cls:
            owner = self.p.resolve_class_chain(chain, self.fn.cls)
            if owner is not None:
                self.reads.add((owner.name, chain[-1]))


class _WorkerScanner:
    """Worker-body pass: only the blocking-in-worker rule (the normal
    rules already ran in pass 1)."""

    def __init__(self, program: Program, fn: FunctionInfo,
                 findings: List[Finding], override_node=None):
        self.p = program
        self.fn = fn
        self.findings = findings
        self.node = override_node if override_node is not None \
            else fn.node

    def scan(self):
        body = self.node if not hasattr(self.node, "body") \
            else self.node.body
        if isinstance(body, list):
            for stmt in body:
                self._scan(stmt, [])
        else:
            self._scan(body, [])

    def _scan(self, node, held: List[str]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            new = list(held)
            for item in node.items:
                self._scan(item.context_expr, held)
                tok = self.p.lock_token(item.context_expr, self.fn)
                if tok:
                    new.append(tok)
            for stmt in node.body:
                self._scan(stmt, new)
            return
        if isinstance(node, ast.Call):
            e = _match_blocking(self.p, self.fn, node,
                                config.WORKER_BLOCKING, held)
            if e is not None:
                chain = attr_chain(node.func)
                what = chain[-1] if chain else "<call>"
                self.findings.append(Finding(
                    checker="lock", rule="blocking-in-worker",
                    file=self.fn.module.relpath, line=node.lineno,
                    scope=self.fn.qualname,
                    message=f"{what}() on a worker-thread job body: "
                            f"{e['why']}"))
        for child in ast.iter_child_nodes(node):
            self._scan(child, held)
