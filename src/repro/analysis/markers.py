"""Static annotation markers consumed by ``repro.analysis``.

Zero-cost at runtime (plain attribute tags); dependency-free so every
core module can import them.  The analyzer reads the DECORATOR SYNTAX
via AST — the runtime attributes exist only so tooling/tests can
introspect live objects.
"""
from typing import Any, Callable, TypeVar

F = TypeVar("F", bound=Callable[..., Any])


def requires_lock(lockname: str) -> Callable[[F], F]:
    """Declare that a function must only run with ``lockname`` held.

    ``lockname`` is the attribute name of the owning lock — ``"_lock"``
    / ``"_cv"`` for instance locks on ``self``, or a module-global name
    (``"_IO_LOCK"``) for module-level functions.  The lock checker
    enforces every call site: lexically inside ``with self.<lockname>``
    (or the module-level ``with <lockname>``), or from another method
    of the same class carrying the same marker / the ``*_locked``
    naming convention.
    """
    def deco(fn: F) -> F:
        fn.__llms_requires_lock__ = lockname
        return fn
    return deco


def requires_serialized(fn: F) -> F:
    """Declare that a function runs only on the dispatcher — i.e. under
    ``ServiceRouter._svc_lock``, the coarse lock that serializes ALL
    service access (DESIGN.md §2).

    The lock checker enforces call sites: from another serialized
    function, from a method holding ``_svc_lock`` (lexically or via
    ``@requires_lock("_svc_lock")``), or from an allowlisted
    single-threaded entry point (``analysis.config``).
    """
    fn.__llms_serialized__ = True
    return fn
