"""Build a Program from the tree and run all four checkers.

Deliberately imports NOTHING outside the stdlib + this package: the CI
analysis job runs it on a bare Python with no jax installed.
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, List, Optional, Tuple

from repro.analysis import baseline as baseline_mod
from repro.analysis import familycheck, jitcheck, lockcheck, sharedstate
from repro.analysis.astpass import Program
from repro.analysis.findings import Finding

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_SCAN = REPO_ROOT / "src" / "repro"
DEFAULT_BASELINE = REPO_ROOT / "analysis_baseline.json"
# the analyzer's own package is config/infrastructure, and fixtures/
# holds KNOWN-BAD reproductions exercised only by --selftest and tests
_EXCLUDE_PARTS = ("analysis",)


def iter_sources(root: Path) -> Iterable[Path]:
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root)
        if rel.parts and rel.parts[0] in _EXCLUDE_PARTS:
            continue
        yield path


def build_program(paths: Optional[List[Path]] = None) -> Program:
    program = Program()
    files = list(paths) if paths else list(iter_sources(DEFAULT_SCAN))
    for path in files:
        try:
            rel = path.resolve().relative_to(REPO_ROOT)
        except ValueError:
            rel = path
        modname = ".".join(rel.with_suffix("").parts)
        if modname.startswith("src."):
            modname = modname[len("src."):]
        program.add_source(path.read_text(), rel.as_posix(), modname)
    return program


def run_checks(program: Program) -> List[Finding]:
    lock_findings, scan = lockcheck.run(program)
    findings = list(lock_findings)
    findings.extend(sharedstate.run(scan))
    findings.extend(jitcheck.run(program))
    findings.extend(familycheck.run(program))
    findings.sort(key=lambda f: f.sort_key())
    return findings


def analyze_source(source: str, relpath: str = "<memory>.py",
                   modname: str = "fixture") -> List[Finding]:
    """Single-module entry point for tests and --selftest."""
    program = Program()
    program.add_source(source, relpath, modname)
    return run_checks(program)


def analyze_paths(paths: List[Path]) -> List[Finding]:
    return run_checks(build_program(paths))


def run_default() -> Tuple[List[Finding], List[Finding]]:
    """Full-tree run diffed against the committed baseline:
    -> (new, grandfathered)."""
    findings = run_checks(build_program())
    baselined = baseline_mod.load(DEFAULT_BASELINE)
    return baseline_mod.diff(findings, baselined)
