"""Thread-shared-state audit (rule ``shared/unguarded-shared-write``).

An attribute is FLAGGED when all of:
  1. it is written without any lock held,
  2. the write happens in (or the attribute is also touched from) a
     function reachable from a worker-thread entry point
     (``config.WORKER_ENTRIES`` + functions the lock scan saw handed
     to ``submit``/``add_done_callback``/``Thread(target=)``), AND the
     attribute is also accessed from the router/scheduler side
     (``config.READER_ENTRY_PREFIXES`` / ``READER_ENTRIES``) — i.e.
     the access genuinely crosses threads,
  3. it is not in ``config.SHARED_STATE_ALLOWLIST`` (every allowlist
     entry carries a one-line justification).

Reachability is a BFS over the name-resolved call graph the lock scan
recorded.  ``__init__`` writes are construction, not sharing, and are
excluded at collection time.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

from repro.analysis import config
from repro.analysis.findings import Finding
from repro.analysis.lockcheck import ScanData


def _reach(roots: Set[str], edges: Dict[str, Set[str]]) -> Set[str]:
    seen = set(roots)
    frontier = list(roots)
    while frontier:
        cur = frontier.pop()
        for nxt in edges.get(cur, ()):
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    return seen


def run(data: ScanData) -> List[Finding]:
    worker_roots: Set[str] = set()
    reader_roots: Set[str] = set()
    for node_id, fn in data.by_ident.items():
        ident = fn.ident
        if fn.worker or ident in config.WORKER_ENTRIES:
            worker_roots.add(node_id)
        if ident in config.READER_ENTRIES or any(
                ident.startswith(p)
                for p in config.READER_ENTRY_PREFIXES):
            reader_roots.add(node_id)

    wreach = _reach(worker_roots, data.edges)
    rreach = _reach(reader_roots, data.edges)

    def accessed(side: Set[str]) -> Set[Tuple[str, str]]:
        keys: Set[Tuple[str, str]] = set()
        for site in data.writes:
            if _node_id(site) in side:
                keys.add(site.key)
        for node_id in side:
            keys.update(data.reads.get(node_id, ()))
        return keys

    worker_keys = accessed(wreach)
    reader_keys = accessed(rreach)

    findings: List[Finding] = []
    emitted: Set[Tuple[str, str, str]] = set()
    for site in data.writes:
        if site.guarded:
            continue
        if site.key in config.SHARED_STATE_ALLOWLIST:
            continue
        nid = _node_id(site)
        crosses = (nid in wreach and site.key in reader_keys) or \
                  (nid in rreach and site.key in worker_keys)
        if not crosses:
            continue
        dedup = (site.fn.module.relpath, site.fn.qualname,
                 f"{site.key[0]}.{site.key[1]}")
        if dedup in emitted:
            continue
        emitted.add(dedup)
        side = "worker thread" if nid in wreach else "router/scheduler"
        findings.append(Finding(
            checker="shared", rule="unguarded-shared-write",
            file=site.fn.module.relpath, line=site.line,
            scope=site.fn.qualname,
            message=f"unguarded write to {site.key[0]}.{site.key[1]} "
                    f"on the {side} side while the other side also "
                    f"touches it; guard it or allowlist with a "
                    f"justification"))
    return findings


def _node_id(site) -> str:
    return f"{site.fn.qualname}@{site.fn.module.modname}"
