"""family-dispatch checker.

Rule:

``string-dispatch``  (F1) a comparison against a ``.family`` attribute
                     (``==``, ``!=``, ``in``, ``not in``) outside the
                     registry/config layer.  PR 10's KVSpec redesign
                     moved every per-family capability into the
                     declarative spec; a family-string comparison in
                     engine code re-creates the ``mc.family ==
                     "dense"`` forks that made adding the seventh
                     model family a cross-layer edit (the old
                     core/executor.py gates live on as
                     ``fixtures/family_dispatch.py``).  Fix: declare
                     the capability as a ``KVSpec`` field and read
                     THAT.

The allowlist (``config.FAMILY_DISPATCH_ALLOWED_FILES`` /
``_PREFIXES``) covers the two legitimate dispatch points — the model
registry, which maps family name -> model class, and the config
tables, which are keyed by family name — plus the spec module's own
docstring examples.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis import config
from repro.analysis.astpass import ModuleInfo, Program, attr_chain
from repro.analysis.findings import Finding

_OPS = {ast.Eq: "==", ast.NotEq: "!=", ast.In: "in", ast.NotIn: "not in"}


def run(program: Program) -> List[Finding]:
    findings: List[Finding] = []
    for mod in program.modules:
        if _allowed(mod.relpath):
            continue
        _Scanner(mod, findings).visit(mod.tree)
    return findings


def _allowed(relpath: str) -> bool:
    if relpath in config.FAMILY_DISPATCH_ALLOWED_FILES:
        return True
    return relpath.startswith(config.FAMILY_DISPATCH_ALLOWED_PREFIXES)


def _family_chain(node):
    """The attr chain when ``node`` is ``<recv>.family`` (or the bare
    name ``family``, the common local-alias form)."""
    chain = attr_chain(node)
    if chain and chain[-1] == "family":
        return chain
    return None


class _Scanner(ast.NodeVisitor):
    """Track the enclosing qualname; flag family-string comparisons."""

    def __init__(self, mod: ModuleInfo, findings: List[Finding]):
        self.mod = mod
        self.findings = findings
        self.stack: List[str] = []

    def _scoped(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_ClassDef = _scoped
    visit_FunctionDef = _scoped
    visit_AsyncFunctionDef = _scoped

    def visit_Compare(self, node: ast.Compare):
        sides = [node.left] + list(node.comparators)
        ops = [type(op) for op in node.ops]
        if any(op in _OPS for op in ops):
            for side in sides:
                chain = _family_chain(side)
                if chain:
                    op = next(_OPS[o] for o in ops if o in _OPS)
                    self.findings.append(Finding(
                        checker="family", rule="string-dispatch",
                        file=self.mod.relpath, line=node.lineno,
                        scope=".".join(self.stack) or "<module>",
                        message=(f"capability fork on "
                                 f"`{'.'.join(chain)} {op} ...`: declare "
                                 f"the capability as a KVSpec field and "
                                 f"branch on the spec")))
                    break
        self.generic_visit(node)
