"""jit-discipline checker.

Rules:

``self-in-traced-fn``   (J1) a function handed to ``jax.jit`` closes
                        over ``self`` — retracing keys on object
                        identity and mutable state silently bakes into
                        the trace.  The executor's idiom is to copy
                        what it needs into locals first
                        (``cs, nl = self.cs, self.n_layers``) or to
                        jit a BOUND leaf method (3+-element chain like
                        ``jax.jit(self.codec.insert)``), both of which
                        pass.
``host-call-in-jit``    (J2) host-side-effect call (print/open/
                        time.*/os.*/FAULTS.*/random.*) inside a traced
                        function: runs once at trace time, then never
                        again.
``unhashable-jit-key``  (J3) a jit-cache access keyed by something
                        unhashable (list/dict/set display) or by
                        ``id(...)`` — the PR 3 ``id(model)`` bug:
                        ids are recycled after GC, so a dead model's
                        cache entry can serve a new model.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis import config
from repro.analysis.astpass import (FunctionInfo, ModuleInfo, Program,
                                    attr_chain)
from repro.analysis.findings import Finding


def run(program: Program) -> List[Finding]:
    findings: List[Finding] = []
    for mod in program.modules:
        for fn in mod.all_functions:
            _scan_fn(program, mod, fn, findings)
    return findings


def _is_jit_call(node: ast.Call) -> bool:
    chain = attr_chain(node.func)
    return bool(chain) and chain[-1] == "jit"


def _scan_fn(program: Program, mod: ModuleInfo, fn: FunctionInfo,
             findings: List[Finding]):
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Call) and _is_jit_call(node):
            for arg in node.args[:1]:
                _check_traced(mod, fn, arg, findings)
        if isinstance(node, ast.Call):
            _check_cache_key(mod, fn, node, findings)
        if isinstance(node, ast.Subscript):
            _check_cache_subscript(mod, fn, node, findings)


# ------------------------------------------------------------------ #
# J1 / J2: the traced callable
# ------------------------------------------------------------------ #
def _check_traced(mod: ModuleInfo, fn: FunctionInfo, arg,
                  findings: List[Finding]):
    body: Optional[ast.AST] = None
    label = "<traced>"
    if isinstance(arg, ast.Lambda):
        body, label = arg.body, "<lambda>"
    elif isinstance(arg, ast.Call):
        # functools.partial(model.step, ...): the bound callable is
        # positional arg 0; partials over module functions resolve below
        chain = attr_chain(arg.func)
        if chain and chain[-1] == "partial" and arg.args:
            _check_traced(mod, fn, arg.args[0], findings)
        return
    elif isinstance(arg, ast.Name):
        target = _lookup_local(fn, arg.id) or mod.functions.get(arg.id)
        if target is not None:
            body, label = target.node, target.qualname
    elif isinstance(arg, ast.Attribute):
        chain = attr_chain(arg)
        if chain and chain[0] == "self" and len(chain) == 2:
            # jax.jit(self.method): the trace captures `self`
            findings.append(Finding(
                checker="jit", rule="self-in-traced-fn",
                file=mod.relpath, line=arg.lineno, scope=fn.qualname,
                message=f"jax.jit(self.{chain[1]}) traces a bound "
                        f"method of the ENGINE object: mutable self "
                        f"state bakes into the trace; jit a leaf "
                        f"callable or copy state to locals first"))
        # 3+-element chains (self.codec.insert) bind a leaf object —
        # accepted; model.step etc. unresolved — accepted
        return
    if body is None:
        return
    self_uses = [n for n in ast.walk(body)
                 if isinstance(n, ast.Name) and n.id == "self"]
    if self_uses:
        findings.append(Finding(
            checker="jit", rule="self-in-traced-fn",
            file=mod.relpath, line=self_uses[0].lineno,
            scope=fn.qualname,
            message=f"traced function {label} closes over `self`: "
                    f"copy the needed fields into locals before "
                    f"defining it"))
    for n in ast.walk(body):
        if isinstance(n, ast.Call):
            why = _host_call(n)
            if why:
                findings.append(Finding(
                    checker="jit", rule="host-call-in-jit",
                    file=mod.relpath, line=n.lineno, scope=fn.qualname,
                    message=f"traced function {label} calls {why}: "
                            f"host side effects run once at trace "
                            f"time, then never again"))


def _lookup_local(fn: FunctionInfo, name: str) -> Optional[FunctionInfo]:
    cur: Optional[FunctionInfo] = fn
    while cur is not None:
        if name in cur.children:
            return cur.children[name]
        cur = cur.parent
    return None


def _host_call(node: ast.Call) -> Optional[str]:
    chain = attr_chain(node.func)
    if not chain:
        return None
    if len(chain) == 1 and chain[0] in config.JIT_HOST_CALL_NAMES:
        return f"{chain[0]}()"
    if len(chain) >= 2:
        if chain[0] in config.JIT_HOST_CALL_ROOTS:
            return ".".join(chain) + "()"
        if chain[:2] in config.JIT_HOST_CALL_CHAINS:
            return ".".join(chain) + "()"
    return None


# ------------------------------------------------------------------ #
# J3: cache-key hashability
# ------------------------------------------------------------------ #
def _is_cache_name(expr) -> bool:
    chain = attr_chain(expr)
    return bool(chain) and \
        config.JIT_CACHE_NAME_HINT in chain[-1].lower()


def _check_cache_key(mod: ModuleInfo, fn: FunctionInfo,
                     node: ast.Call, findings: List[Finding]):
    """``self._jit_cache_get(key, ...)`` / ``cache.get(key)`` style."""
    if not _is_cache_name(node.func):
        return
    if not node.args:
        return
    _check_key_expr(mod, fn, node.args[0], findings)


def _check_cache_subscript(mod: ModuleInfo, fn: FunctionInfo,
                           node: ast.Subscript,
                           findings: List[Finding]):
    """``self._cache[key]`` style."""
    if not _is_cache_name(node.value):
        return
    _check_key_expr(mod, fn, node.slice, findings)


def _check_key_expr(mod: ModuleInfo, fn: FunctionInfo, key,
                    findings: List[Finding]):
    resolved = key
    if isinstance(key, ast.Name):
        resolved = _last_assignment(fn, key.id) or key
    bad = _unhashable_reason(resolved)
    if bad:
        findings.append(Finding(
            checker="jit", rule="unhashable-jit-key",
            file=mod.relpath, line=key.lineno, scope=fn.qualname,
            message=f"jit-cache key {bad}; keys must be stable "
                    f"hashable values (tuples of config scalars), "
                    f"never identities or mutable containers"))


def _last_assignment(fn: FunctionInfo, name: str):
    """Last `name = <expr>` in the function body before use (textual)."""
    found = None
    for n in ast.walk(fn.node):
        if isinstance(n, ast.Assign):
            for tgt in n.targets:
                if isinstance(tgt, ast.Name) and tgt.id == name:
                    found = n.value
    return found


def _unhashable_reason(expr) -> Optional[str]:
    if isinstance(expr, (ast.List, ast.ListComp)):
        return "is a list (unhashable)"
    if isinstance(expr, (ast.Dict, ast.DictComp)):
        return "is a dict (unhashable)"
    if isinstance(expr, (ast.Set, ast.SetComp)):
        return "is a set (unhashable)"
    if isinstance(expr, ast.Call) and \
            isinstance(expr.func, ast.Name) and expr.func.id == "id":
        return "uses id(...) (recycled after GC — the PR 3 stale-" \
               "cache bug)"
    if isinstance(expr, ast.Tuple):
        for elt in expr.elts:
            bad = _unhashable_reason(elt)
            if bad:
                return bad
    return None
