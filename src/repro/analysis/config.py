"""Repo-specific configuration for the analyzer.

This is a REPO-LOCAL tool: precision comes from encoding the stack's
own conventions (attribute names, lock roles, thread entry points)
rather than whole-program type inference.  Everything an operator
might tune lives here as plain data.
"""
from __future__ import annotations

# --------------------------------------------------------------------- #
# attribute-name -> class map.  The stack wires layers together through
# a fixed set of attribute names (DESIGN.md §1); the checkers use this
# to resolve `self.store.write(...)`-style cross-class calls and lock
# expressions like `with self.store._lock`.
# --------------------------------------------------------------------- #
ATTR_TYPES = {
    "store": "DiskStore",
    "swapper": "AsyncSwapper",
    "res": "ResidencyEngine",
    "svc": "LLMService",
    "mem": "MemoryManager",
    "ctxs": "ContextStore",
    "exe": "ModelExecutor",
    "router": "ServiceRouter",
    # NOT "pool": ThreadPoolExecutor in AsyncSwapper, PagePool in
    # ResidencyEngine — ambiguous by design, so left unresolved.
}

# --------------------------------------------------------------------- #
# Coarse locks: held across entire service slices BY DESIGN (the
# router's `_svc_lock` serializes ALL service access, including disk
# reads and jitted execution — that serialization IS the engine's
# concurrency model, DESIGN.md §2).  Exempt from blocking-under-lock.
# --------------------------------------------------------------------- #
COARSE_LOCKS = {
    "ServiceRouter._svc_lock",
}

# --------------------------------------------------------------------- #
# Blocking-call registry (rule lock/blocking-under-lock).  Matching is
# structural: `attr` matches any `<recv>.<attr>(...)` call (optionally
# constrained to receivers whose chain mentions one of `recv`);
# `name` matches a bare `name(...)` call; `attr_suffix` matches jitted
# entry points by the repo's `*_fn` naming convention.  Entries with
# `allow_held` are permitted when the receiver IS a lock currently
# held — `self._cv.wait()` inside `with self._cv` releases the lock
# while blocked (the Condition protocol), so it cannot hold anything
# up.
# --------------------------------------------------------------------- #
BLOCKING_CALLS = [
    {"attr": "result", "why": "Future.result() blocks"},
    {"attr": "wait", "allow_held": True,
     "why": "blocking wait (Future/Event/AsyncSwapper)"},
    {"attr": "wait_for", "allow_held": True,
     "why": "Condition.wait_for blocks"},
    {"attr": "flush", "why": "AsyncSwapper.flush waits on all pending IO"},
    {"attr": "join", "not_recv": ("path", "os"),
     "why": "thread join blocks"},
    {"attr": "sleep", "why": "sleep under a lock stalls every waiter"},
    {"attr": "read", "recv": ("store", "swapper"),
     "why": "disk read (DiskStore/AsyncSwapper) under a lock"},
    {"attr": "write", "recv": ("store",),
     "why": "disk write (DiskStore) under a lock"},
    {"attr": "delete", "recv": ("store",),
     "why": "disk delete (DiskStore) under a lock"},
    {"name": "write_chunk_file", "why": "chunk-file IO under a lock"},
    {"name": "read_chunk_file", "why": "chunk-file IO under a lock"},
    {"name": "verify_chunk_file", "why": "chunk-file IO under a lock"},
    {"name": "with_retries",
     "why": "retry loop sleeps between attempts"},
    {"attr_suffix": "_fn",
     "why": "jitted-entry execution under a lock"},
]

# Subset that is ALSO forbidden inside worker-pool job bodies and
# done-callbacks (rule lock/blocking-in-worker — the PR 3 deadlock
# class: a pool worker parked in `fut.result()` while the job that
# would resolve it sits queued behind it).  Disk IO is fine on a
# worker (that's its job); synchronizing on OTHER pool work is not.
WORKER_BLOCKING = [
    {"attr": "result", "why": "worker parked in Future.result() "
     "deadlocks the pool (PR 3 class)"},
    {"attr": "wait", "allow_held": True,
     "why": "worker blocking on AsyncSwapper/Future wait"},
    {"attr": "flush", "why": "worker waiting on all pending IO"},
    {"attr": "join", "not_recv": ("path", "os"),
     "why": "worker joining a thread"},
]

# --------------------------------------------------------------------- #
# Thread-shared-state audit (rule shared/unguarded-shared-write).
#
# Worker entries: functions that RUN on non-dispatcher threads — pool
# job bodies (AsyncSwapper submits `DiskStore.write/read/delete` and
# the chunk-file IO functions as jobs), done-callbacks, and thread
# targets.  Functions passed to `.submit(...)`, `.add_done_callback(..)`
# and `threading.Thread(target=...)` are discovered automatically; this
# list seeds the entries that only dynamic dispatch reaches.
# --------------------------------------------------------------------- #
WORKER_ENTRIES = [
    "AsyncSwapper._run_job",
    "DiskStore.write",
    "DiskStore.read",
    "DiskStore.delete",
    "write_chunk_file",
    "read_chunk_file",
    "verify_chunk_file",
    "count_io",
    "LayerFeed._run",
    # AsyncSwapper.on_job_error callback: ResidencyEngine wires
    # `swapper.on_job_error = self._on_io_error` — invoked from a pool
    # worker when a job exhausts its retry budget
    "ResidencyEngine._on_io_error",
]

# Reader entries: the router/dispatcher side — everything reachable
# from the service surface plus the loadgen driver hooks and report
# builders (the PR 7 snapshot-race class).
READER_ENTRY_PREFIXES = [
    "ServiceRouter.",
    "AppSession.",
    "LLMService.",
    "ResidencyEngine.",
    "GenerationStream.",
    "EventLog.",
]
READER_ENTRIES = [
    "run_scenario",
    "replay_trace",
    "build_report",
    "io_counters",
]

# (class-or-module, attribute) -> one-line justification.  Every entry
# is an AUDITED decision: either a proven happens-before exists, or a
# torn read is harmless by design.  New unguarded shared writes that
# are NOT here (and not baselined) fail CI.
SHARED_STATE_ALLOWLIST = {
    ("AsyncSwapper", "_shutdown"):
        "monotonic latch, flipped once after flush(); a stale read "
        "only delays a cancel by one callback hop",
    ("AsyncSwapper", "on_job_error"):
        "wired once in ResidencyEngine.__init__ before any IO is "
        "submitted; never reassigned while workers run",
    ("LayerFeed", "_error"):
        "written by the IO thread before the per-layer Event.set(); "
        "readers check it only after Event.wait() (happens-before)",
    ("ResidencyEngine", "degraded"):
        "reads are racy ON PURPOSE: a stale False admits one more "
        "write that fails identically; writes are lock-serialized",
    ("ResidencyEngine", "aot_enabled"):
        "same monotonic-flag pattern as `degraded` (common writer "
        "lock; racy reads shed at most one extra flush)",
    # loadgen tier (satellite audit, PR 7 snapshot-race class): the
    # scenario driver runs the router INLINE (start=False), so every
    # hook (on_begin/on_round/on_preempt/on_complete), the virtual
    # clock, and the event log execute on the pump thread — there is
    # no second scheduler thread to race.  The only cross-thread
    # traffic is the swap tier's, which `io_counters()` reads under
    # `_IO_LOCK` and `DiskStore.total_bytes` sums under `_lock`.
    ("EventLog", "n"):
        "driver runs the router inline (start=False): hooks and log "
        "are single-threaded by construction",
    ("EventLog", "lines"):
        "same single-threaded-driver argument as EventLog.n",
    ("VirtualClock", "t"):
        "advanced only from driver hooks on the pump thread; the "
        "virtual clock never crosses threads",
    ("repro.core.restore", "_BW"):
        "bench-setup throttle knob, set before the workload starts; "
        "never written concurrently with IO",
    ("repro.core.restore", "_LAT"):
        "same bench-setup argument as _BW",
}

# --------------------------------------------------------------------- #
# Serialized-surface entry points (rule lock/serialized-call): callers
# allowed to invoke @requires_serialized methods WITHOUT holding
# `_svc_lock`, because they own the only thread that ever touches the
# service (single-threaded scripts, inline drivers, fixtures).
# --------------------------------------------------------------------- #
SERIALIZED_CALLER_ALLOWLIST = {
    # loadgen drivers run the router inline (start=False): the pump
    # loop IS the dispatcher thread, no second service thread exists
    "run_scenario",
    "replay_trace",
    # single-threaded setup/launch entry points: they touch the service
    # before any worker or router thread has been started
    "main",
    "build_service",
}

# --------------------------------------------------------------------- #
# jit discipline
# --------------------------------------------------------------------- #
# host-side-effect roots forbidden inside functions passed to jax.jit
JIT_HOST_CALL_NAMES = {"print", "open", "input"}
JIT_HOST_CALL_ROOTS = {"time", "os", "FAULTS", "random"}
JIT_HOST_CALL_CHAINS = {("np", "random"), ("numpy", "random")}
# names treated as jit-cache accessors for key-hashability checking
JIT_CACHE_NAME_HINT = "cache"

# --------------------------------------------------------------------- #
# family dispatch (rule family/string-dispatch)
# --------------------------------------------------------------------- #
# The ONLY places allowed to compare `.family` strings: the registry
# (maps family name -> model class), the spec module itself, and the
# model/config constructors that declare each family's KVSpec.  Engine
# code must consume the declarative spec, never the family string —
# PR 10's api_redesign exists to keep capability knowledge out of the
# executor/residency layers (the old core/executor.py:121/:201 gates
# are preserved as the fixtures/family_dispatch.py reproduction).
FAMILY_DISPATCH_ALLOWED_FILES = {
    "src/repro/models/registry.py",
    "src/repro/models/kvspec.py",
}
FAMILY_DISPATCH_ALLOWED_PREFIXES = (
    "src/repro/configs/",    # arch tables keyed by family name
)
