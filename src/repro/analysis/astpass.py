"""Shared AST collection layer: parse every module once, index classes,
locks, methods, markers, and nested functions for the checkers.

Resolution is deliberately name-based (no import graph, no type
inference): the stack wires its layers through a FIXED vocabulary of
attribute names (``config.ATTR_TYPES``), so ``self.store.write(...)``
resolves by convention.  Unresolvable receivers stay unresolved — the
checkers treat them conservatively per rule.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import config

# call expressions whose assignment marks an attribute/global as a lock
LOCK_FACTORIES = {"Lock", "RLock", "Condition", "OrderedLock",
                  "witness_lock", "witness_rlock", "witness_condition"}


def attr_chain(node) -> Optional[Tuple[str, ...]]:
    """``self.store._lock`` -> ("self", "store", "_lock"); None when the
    expression is not a plain Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return tuple(parts)
    return None


@dataclass
class FunctionInfo:
    name: str
    qualname: str                       # "Class.method" | "fn" | "fn.<locals>.g"
    node: ast.AST                       # FunctionDef / AsyncFunctionDef
    module: "ModuleInfo"
    cls: Optional["ClassInfo"]
    parent: Optional["FunctionInfo"] = None
    requires_lock: Optional[str] = None
    serialized: bool = False
    worker: bool = False                # runs on a pool/IO thread
    children: Dict[str, "FunctionInfo"] = field(default_factory=dict)

    @property
    def ident(self) -> str:
        """Config-facing identity: Class.method or bare function name."""
        if self.cls is not None and self.parent is None:
            return f"{self.cls.name}.{self.name}"
        return self.qualname


@dataclass
class ClassInfo:
    name: str
    module: "ModuleInfo"
    node: ast.ClassDef
    lock_attrs: Dict[str, str] = field(default_factory=dict)  # attr->kind
    methods: Dict[str, FunctionInfo] = field(default_factory=dict)


@dataclass
class ModuleInfo:
    relpath: str                        # repo-relative posix path
    modname: str                        # "repro.core.swap"
    tree: ast.Module
    classes: Dict[str, ClassInfo] = field(default_factory=dict)
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    module_locks: Set[str] = field(default_factory=set)
    mutable_globals: Set[str] = field(default_factory=set)
    all_functions: List[FunctionInfo] = field(default_factory=list)


class Program:
    """All modules of one analysis run, with cross-module name indexes."""

    def __init__(self):
        self.modules: List[ModuleInfo] = []
        self.classes: Dict[str, ClassInfo] = {}
        self.functions: Dict[str, FunctionInfo] = {}  # module-level by name

    def add_source(self, source: str, relpath: str, modname: str):
        tree = ast.parse(source, filename=relpath)
        mod = ModuleInfo(relpath=relpath, modname=modname, tree=tree)
        _Collector(mod).visit(tree)
        self.modules.append(mod)
        for cname, cinfo in mod.classes.items():
            self.classes.setdefault(cname, cinfo)
        for fname, finfo in mod.functions.items():
            self.functions.setdefault(fname, finfo)
        return mod

    # -- resolution helpers -------------------------------------------- #
    def resolve_class_chain(self, chain: Tuple[str, ...],
                            cls: Optional[ClassInfo]) -> Optional[ClassInfo]:
        """Resolve the class owning ``chain[-1]`` for a chain rooted at
        ``self`` (``("self", "store", "X")`` -> DiskStore)."""
        if not chain or chain[0] != "self":
            return None
        cur = cls
        for mid in chain[1:-1]:
            cname = config.ATTR_TYPES.get(mid)
            cur = self.classes.get(cname) if cname else None
            if cur is None:
                return None
        return cur

    def resolve_call(self, call: ast.Call,
                     fn: FunctionInfo) -> Optional[FunctionInfo]:
        """Resolve a call expression to a FunctionInfo, or None."""
        chain = attr_chain(call.func)
        if chain is None:
            return None
        if len(chain) == 1:
            name = chain[0]
            cur = fn
            while cur is not None:              # nested defs shadow module
                if name in cur.children:
                    return cur.children[name]
                cur = cur.parent
            got = fn.module.functions.get(name)
            return got if got is not None else self.functions.get(name)
        owner = self.resolve_class_chain(chain, fn.cls)
        if owner is not None:
            return owner.methods.get(chain[-1])
        return None

    def lock_token(self, expr, fn: FunctionInfo) -> Optional[str]:
        """Canonical token for a lock expression, e.g.
        ``DiskStore._lock`` / ``repro.core.restore:_IO_LOCK`` /
        ``?._lock`` (shape-matched but unresolved owner)."""
        chain = attr_chain(expr)
        if chain is None:
            return None
        if len(chain) == 1:
            name = chain[0]
            if name in fn.module.module_locks:
                return f"{fn.module.modname}:{name}"
            if "lock" in name.lower():
                return f"?:{name}"
            return None
        last = chain[-1]
        owner = self.resolve_class_chain(chain, fn.cls)
        if owner is not None and last in owner.lock_attrs:
            return f"{owner.name}.{last}"
        if "lock" in last.lower() or last == "_cv":
            return f"?.{last}"
        return None

    def contract_token(self, fn: FunctionInfo) -> Optional[str]:
        """The lock a function's CONTRACT says is held on entry:
        from ``@requires_lock`` or the ``*_locked`` naming convention.
        ``"?"`` = convention applies but the owning lock is ambiguous
        (any held lock satisfies the call-site check)."""
        if fn.requires_lock:
            ln = fn.requires_lock
            if fn.cls is not None:
                return f"{fn.cls.name}.{ln}"
            return f"{fn.module.modname}:{ln}"
        if fn.name.endswith("_locked"):
            if fn.cls is not None and len(fn.cls.lock_attrs) == 1:
                only = next(iter(fn.cls.lock_attrs))
                return f"{fn.cls.name}.{only}"
            return "?"
        return None


def _decorator_markers(node) -> Tuple[Optional[str], bool]:
    """-> (requires_lock name, serialized) from a def's decorators."""
    req, ser = None, False
    for deco in node.decorator_list:
        if isinstance(deco, ast.Call):
            chain = attr_chain(deco.func)
            if chain and chain[-1] == "requires_lock" and deco.args:
                a0 = deco.args[0]
                if isinstance(a0, ast.Constant) and isinstance(a0.value, str):
                    req = a0.value
        else:
            chain = attr_chain(deco)
            if chain and chain[-1] == "requires_serialized":
                ser = True
    return req, ser


def _is_lock_factory(value) -> Optional[str]:
    """-> lock kind if ``value`` is a lock-constructing call."""
    if not isinstance(value, ast.Call):
        return None
    chain = attr_chain(value.func)
    if not chain or chain[-1] not in LOCK_FACTORIES:
        return None
    name = chain[-1]
    if name in ("Condition", "witness_condition"):
        return "condition"
    if name in ("RLock", "witness_rlock"):
        return "rlock"
    return "lock"


class _Collector(ast.NodeVisitor):
    """One pass over a module: classes, locks, functions (incl. nested),
    markers, mutable module globals."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.cls: Optional[ClassInfo] = None
        self.fn: Optional[FunctionInfo] = None

    # -- module / class level ------------------------------------------ #
    def visit_Module(self, node):
        for stmt in node.body:
            if isinstance(stmt, ast.Assign):
                self._module_assign(stmt)
            self.visit(stmt)

    def _module_assign(self, stmt: ast.Assign):
        for tgt in stmt.targets:
            if not isinstance(tgt, ast.Name):
                continue
            if _is_lock_factory(stmt.value):
                self.mod.module_locks.add(tgt.id)
            elif isinstance(stmt.value, (ast.Dict, ast.List, ast.Set,
                                         ast.DictComp, ast.ListComp,
                                         ast.SetComp)):
                self.mod.mutable_globals.add(tgt.id)

    def visit_ClassDef(self, node):
        prev_cls, prev_fn = self.cls, self.fn
        cinfo = ClassInfo(name=node.name, module=self.mod, node=node)
        # nested classes are indexed flat (none in this repo's core)
        self.mod.classes[node.name] = cinfo
        self.cls, self.fn = cinfo, None
        self.generic_visit(node)
        self.cls, self.fn = prev_cls, prev_fn

    # -- functions ------------------------------------------------------ #
    def _enter_function(self, node):
        req, ser = _decorator_markers(node)
        if self.fn is not None:
            qual = f"{self.fn.qualname}.<locals>.{node.name}"
        elif self.cls is not None:
            qual = f"{self.cls.name}.{node.name}"
        else:
            qual = node.name
        finfo = FunctionInfo(name=node.name, qualname=qual, node=node,
                             module=self.mod, cls=self.cls,
                             parent=self.fn, requires_lock=req,
                             serialized=ser)
        if self.fn is not None:
            self.fn.children[node.name] = finfo
        elif self.cls is not None:
            self.cls.methods[node.name] = finfo
        else:
            self.mod.functions[node.name] = finfo
        self.mod.all_functions.append(finfo)
        return finfo

    def visit_FunctionDef(self, node):
        finfo = self._enter_function(node)
        prev = self.fn
        self.fn = finfo
        # inside __init__, detect `self.X = threading.Lock()` etc.
        if self.cls is not None and prev is None:
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Assign):
                    kind = _is_lock_factory(stmt.value)
                    if kind:
                        for tgt in stmt.targets:
                            ch = attr_chain(tgt)
                            if ch and len(ch) == 2 and ch[0] == "self":
                                self.cls.lock_attrs[ch[1]] = kind
        self.generic_visit(node)
        self.fn = prev

    visit_AsyncFunctionDef = visit_FunctionDef
