"""From-scratch optimizers: AdamW and 8-bit block-quantized AdamW.

The 8-bit variant stores both moments as int8 codes with per-row fp32
scales (symmetric, max-abs over the last dim) — the same codec family as
the paper's KV chunks, applied to optimizer state.  For the 400B-class
assigned archs this is what makes the optimizer fit the pod:
  bf16 params (2B) + int8 mu (1B) + int8 nu (1B) ~ 1.6 TB for llama4-400B
  vs 4.8 TB for fp32 Adam — DESIGN.md §6.

Both variants are pure pytree->pytree functions, jit/pjit-safe; moment
trees mirror the param tree so the sharding rules apply leaf-wise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    quantized: bool = False          # 8-bit moments


def _q8(x):
    """(codes int8, scale fp32 per-row) symmetric over the last dim."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=False) / 127.0
    s = jnp.maximum(s, 1e-12)
    codes = jnp.clip(jnp.round(x / s[..., None]), -127, 127).astype(jnp.int8)
    return codes, s.astype(jnp.float32)


def _dq8(codes, scale):
    return codes.astype(jnp.float32) * scale[..., None]


def _q8v(x):
    """Second-moment codec: quantize sqrt(v), not v.  v's intra-row
    dynamic range is squared, so linear int8 rounds small entries to 0
    and m/(sqrt(v)+eps) explodes; in the sqrt domain an entry survives
    down to (max/254)^2 of the row max instead of max/254."""
    r = jnp.sqrt(jnp.maximum(x, 0.0))
    s = jnp.maximum(jnp.max(r, axis=-1) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(r / s[..., None]), 0, 127).astype(jnp.int8)
    return codes, s.astype(jnp.float32)


def _dq8v(codes, scale):
    r = codes.astype(jnp.float32) * scale[..., None]
    return r * r


def init_state(params: PyTree, cfg: OptConfig) -> Dict[str, PyTree]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if not cfg.quantized:
        return {"params": params,
                "mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}
    return {
        "params": params,
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8), params),
        "mu_scale": jax.tree.map(
            lambda p: jnp.zeros(p.shape[:-1], jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8), params),
        "nu_scale": jax.tree.map(
            lambda p: jnp.zeros(p.shape[:-1], jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def apply_updates(state: Dict[str, PyTree], grads: PyTree,
                  cfg: OptConfig) -> Tuple[Dict[str, PyTree], Dict]:
    """One AdamW step (grad clip + warmup schedule built in)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    if not cfg.quantized:
        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, state["params"], grads, state["mu"],
                           state["nu"])
        params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        new = {"params": params, "mu": mu, "nu": nu, "step": step}
    else:
        def upd(p, g, mq, ms, vq, vs):
            g = g.astype(jnp.float32) * scale
            m = b1 * _dq8(mq, ms) + (1 - b1) * g
            v = b2 * _dq8v(vq, vs) + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(jnp.maximum(v, 0.0) / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            p2 = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            mq2, ms2 = _q8(m)
            vq2, vs2 = _q8v(v)
            return p2, mq2, ms2, vq2, vs2

        out = jax.tree.map(upd, state["params"], grads, state["mu"],
                           state["mu_scale"], state["nu"],
                           state["nu_scale"])
        pick = lambda i: jax.tree.map(
            lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
        new = {"params": pick(0), "mu": pick(1), "mu_scale": pick(2),
               "nu": pick(3), "nu_scale": pick(4), "step": step}
    return new, {"grad_norm": gnorm, "lr": lr}
