"""Fault-tolerant checkpointing: atomic, async, elastic.

* atomic: write to ``step_NNN.tmp`` then os.replace — a crash mid-write
  never corrupts the latest checkpoint.
* async: ``save_async`` snapshots to host numpy and hands the file write
  to a background thread; the train loop never blocks on disk.
* elastic: checkpoints are device-layout-free numpy trees; ``restore``
  returns host arrays that the caller ``jax.device_put``s under ANY mesh
  — restoring a 4-way run onto 2 devices (or a different DP size) is
  just a different sharding at load (tested in tests/test_checkpoint.py).
* GC: ``keep`` most recent checkpoints are retained.
"""
from __future__ import annotations

import os
import pickle
import re
import threading
from typing import Any, Optional

import jax
import numpy as np

_PAT = re.compile(r"step_(\d+)\.pkl$")
_save_lock = threading.Lock()
_pending: list = []


def _to_host(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(x), tree)


def save(ckpt_dir: str, step: int, state: Any, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    host = _to_host(state)
    path = os.path.join(ckpt_dir, f"step_{step}.pkl")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        pickle.dump(host, f, protocol=pickle.HIGHEST_PROTOCOL)
    os.replace(tmp, path)
    _gc(ckpt_dir, keep)
    return path


def save_async(ckpt_dir: str, step: int, state: Any, keep: int = 3):
    host = _to_host(state)                      # snapshot before returning

    def work():
        with _save_lock:
            os.makedirs(ckpt_dir, exist_ok=True)
            path = os.path.join(ckpt_dir, f"step_{step}.pkl")
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                pickle.dump(host, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
            _gc(ckpt_dir, keep)

    t = threading.Thread(target=work, daemon=True)
    t.start()
    _pending.append(t)
    return t


def flush():
    for t in list(_pending):
        t.join()
    _pending.clear()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := _PAT.search(f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None) -> Any:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with open(os.path.join(ckpt_dir, f"step_{step}.pkl"), "rb") as f:
        return pickle.load(f)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(m.group(1)) for f in os.listdir(ckpt_dir)
                   if (m := _PAT.search(f)))
    for s in steps[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f"step_{s}.pkl"))
        except FileNotFoundError:
            pass
