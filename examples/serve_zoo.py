"""Serve THREE model families behind one ServiceRouter (DESIGN.md §4).

Builds a ZooService — dense chat + MLA latent-cache + RWKV6
constant-state members sharing ONE byte budget, ONE swap tier, ONE
eviction order — and drives the ``mixed_zoo`` scenario through the
virtual-clock harness.  The router never learns which family a context
belongs to: routing is by context ownership, capabilities come from
each family's declarative KVSpec.

  PYTHONPATH=src python examples/serve_zoo.py [--contexts 9 --calls 18]
"""
import argparse

import jax

from repro.configs import get_config, reduced
from repro.loadgen import get_scenario, run_scenario
from repro.loadgen.driver import (bind_apps_by_ctx, build_zoo_service,
                                  make_events)
from repro.models.registry import build_model

ZOO_ARCHS = {"dense": "llama2-7b",
             "mla_moe": "deepseek-v2-lite-16b",
             "rwkv6": "rwkv6-1.6b"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--contexts", type=int, default=9)
    ap.add_argument("--calls", type=int, default=18)
    args = ap.parse_args()

    spec = get_scenario("mixed_zoo", n_contexts=args.contexts,
                        n_calls=args.calls)
    cfgs = {fam: reduced(get_config(arch))
            for fam, arch in ZOO_ARCHS.items()}
    vocab = min(cfg.vocab for cfg in cfgs.values())
    models = {}
    for fam, cfg in cfgs.items():
        model = build_model(cfg)
        models[fam] = (model, model.init(jax.random.PRNGKey(0)))

    events = bind_apps_by_ctx(make_events(spec, vocab), spec)
    svc = build_zoo_service(spec, models)
    with svc:
        rep = run_scenario(spec, svc, vocab, events=events)
        stats = svc.stats()

    print(f"mixed zoo: {len(stats['zoo_families'])} families "
          f"{tuple(stats['zoo_families'])} behind one router")
    for fam, st in stats["families"].items():
        print(f"  {fam:8s} contexts={st['contexts']:2d} "
              f"calls={st['total_calls']:3d} "
              f"resident_bytes={st['resident_bytes']}")
    print(f"  budget: mem_used={stats['mem_used']} / "
          f"{spec.memory_budget} (ok={rep['budget']['ok']})")
    print(f"  errors={rep['streams']['errors']} "
          f"stuck={rep['streams']['stuck']} "
          f"quant_resident_chunks={stats['quant_resident_chunks']}")
    if rep["streams"]["errors"] or rep["streams"]["stuck"]:
        raise SystemExit("zoo smoke FAILED: errors or stuck streams")
    served = {f: st["total_calls"] for f, st in stats["families"].items()}
    if len(served) < 3 or not all(served.values()):
        raise SystemExit(f"zoo smoke FAILED: idle families {served}")
    print("zoo smoke OK")


if __name__ == "__main__":
    main()
