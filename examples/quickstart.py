"""Quickstart: the LLMaaS workflow from the paper's Fig. 3, end to end.

1. build a (reduced) model and start an LLMService,
2. create two persistent contexts (two "apps"),
3. chat across them — contexts keep their history between calls,
4. watch chunks get tolerance-aware compressed, AoT-swapped, and
   restored through the swapping-recompute pipeline under a tight
   memory budget.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.service import LLMSConfig, LLMService
from repro.models.registry import build_model


def main():
    cfg = reduced(get_config("llama2-7b"))      # the paper's model, tiny
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    with LLMService(model, params, LLMSConfig(
            policy="llms",
            max_ctx_len=128,
            memory_budget=24_000,               # tight: forces swapping
            swap_dir=tempfile.mkdtemp(prefix="llms_quickstart_"))) as service:
        service.profile_pipeline()              # paper §3.3.i

        # two apps, each holding a persistent context (Table 1 API)
        chat = service.bindLLMService("chat-app").newLLMCtx(
            system_prompt=[1, 2, 3, 4])
        mail = service.bindLLMService("mail-app").newLLMCtx()

        rng = np.random.RandomState(0)
        for turn in range(4):
            for name, stub in (("chat", chat), ("mail", mail)):
                prompt = rng.randint(5, cfg.vocab, size=10).tolist()
                _, reply = service.callLLM(stub, prompt, max_new_tokens=6)
                r = service.records[-1]
                ctx = service.contexts[stub.ctx_id]
                levels = [m.bits for _, m in sorted(ctx.chunks.items())]
                print(f"turn {turn} {name}: reply={reply} "
                      f"switch={r['switch_s']*1e3:.2f}ms "
                      f"ctx_tokens={ctx.n_tokens} chunk_bits={levels}")

        print("\nservice stats:", service.stats())


if __name__ == "__main__":
    main()
