"""Serve a synthesized multi-app context-switching trace (paper §4/§5)
through the ServiceRouter and compare LLMS against a baseline policy
side by side.  Contexts are split across a foreground and a background
app session so the router's per-priority accounting is visible;
``--slice-steps`` turns on decode-slice dispatch so the sliced request
path is exercised too.

  PYTHONPATH=src:. python examples/serve_trace.py [--policy vllm_sq]
"""
import argparse
import tempfile

import jax

from repro.configs import get_config, reduced
from repro.core.restore import set_disk_throttle
from repro.core.service import LLMSConfig, LLMService, POLICIES
from repro.loadgen import replay_trace
from repro.models.registry import build_model
from repro.trace.synth import synthesize


def run(policy: str, events, model, params, budget: int,
        slice_steps: int = 0, decode_batch: int = 1,
        paged_pool: bool = True):
    with LLMService(model, params, LLMSConfig(
            policy=policy, max_ctx_len=128, memory_budget=budget,
            decode_batch=decode_batch, paged_pool=paged_pool,
            swap_dir=tempfile.mkdtemp())) as svc:
        if svc.cfg.use_pipeline:
            svc.profile_pipeline()
        # flood + drain through the single replay implementation
        # (repro.loadgen): everything admitted up front, fg/bg split by
        # context parity, warm pass first so jit stays out of the
        # measured pass
        return replay_trace(
            svc, events, mode="flood", max_new=4, warm=True, predict=True,
            slice_steps=slice_steps,
            apps=(("chat", "foreground"), ("agent", "background")),
            route=lambda ev: "chat" if ev.ctx_id % 2 == 0 else "agent")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="vllm_sq", choices=POLICIES)
    ap.add_argument("--contexts", type=int, default=4)
    ap.add_argument("--calls", type=int, default=16)
    ap.add_argument("--slice-steps", type=int, default=2,
                    help="decode-slice length (0 = whole-generation)")
    ap.add_argument("--decode-batch", type=int, default=1,
                    help="decode slots: queued generations batch up to "
                         "this many per jitted step")
    ap.add_argument("--paged-pool", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="decode over the unified paged KV pool "
                         "(--no-paged-pool restores per-slot caches)")
    args = ap.parse_args()

    cfg = reduced(get_config("llama2-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    set_disk_throttle(25e6, 2e-4)               # UFS/SATA-class tier
    events = synthesize(args.contexts, args.calls, cfg.vocab,
                        pattern="markov", scale=0.05, seed=0)
    budget = 30_000
    for policy in ("llms", args.policy):
        st = run(policy, events, model, params, budget,
                 slice_steps=args.slice_steps,
                 decode_batch=args.decode_batch,
                 paged_pool=args.paged_pool)
        print(f"{policy:10s} mean switch {st['switch_mean_s']*1e3:8.3f} ms  "
              f"p99 {st['switch_p99_s']*1e3:8.3f} ms  "
              f"mem {st['mem_used']:>8d} B")
        if st.get("paged_pool"):
            print(f"  pool       bf16 {st['pool_pages16_used']}/"
                  f"{st['pool_pages16_total']} pages  int8 "
                  f"{st['pool_pages8_used']}/{st['pool_pages8_total']}  "
                  f"faults={st['pool_page_faults']}  "
                  f"table-read switch-ins={st['pool_pt_switch_ins']}  "
                  f"admit switch-ins={st['pool_admit_switch_ins']}  "
                  f"reclaims={st['pool_reclaims']}  mid-slice joins="
                  f"{st['router'].get('joins_mid_slice', 0)}")
        qd = st["router"].get("queue_depth")
        if qd:
            print(f"  queue      depth mean {qd['mean']:5.2f}  p95 "
                  f"{qd['p95']:4.1f}  max {qd['max']:3d}  "
                  f"({qd['samples']} round samples)")
        pre = st["router"]["preemptions_by_priority"]
        for prio in ("foreground", "background"):
            if prio in st["router"]:
                r = st["router"][prio]
                ttft = r.get("ttft_mean_s")
                print(f"  {prio:10s} calls={r['calls']:3d}"
                      f" latency {r['latency_mean_s']*1e3:8.3f} ms"
                      f" wait p95 {r['wait_p95_s']*1e3:8.3f} ms"
                      f" preempted={pre.get(prio, 0)}"
                      + (f" ttft {ttft*1e3:8.3f} ms"
                         if ttft is not None else ""))


if __name__ == "__main__":
    main()
