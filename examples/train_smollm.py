"""End-to-end training driver example: train the reduced smollm-360m on
the synthetic Markov corpus for a few hundred steps with checkpointing
and resume (fault-tolerance path).

  PYTHONPATH=src python examples/train_smollm.py --steps 200
  PYTHONPATH=src python examples/train_smollm.py --steps 300 --resume

This is a thin veneer over ``repro.launch.train`` — the same driver the
production mesh uses (the dry-run lowers its train_step on 256 chips).
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if "--reduced" not in sys.argv:
        sys.argv.append("--reduced")
    if not any(a.startswith("--arch") for a in sys.argv):
        sys.argv += ["--arch", "smollm-360m"]
    main()
